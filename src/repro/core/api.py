"""AutoTinyClassifier — the end-to-end toolflow of Fig. 7 as a public API.

fit(X, y):
  1. for each candidate (encoding strategy, bits/input): fit the encoder on
     the training split, pack the bits, 50/50 train/val split (§3.3),
  2. run the 1+λ EGGP search (§3) — optionally island-parallel on a mesh,
  3. keep the circuit with the best validation fitness across encodings
     (paper §5.2: "experiments report the best-achieved accuracy across the
     available encoding strategies with two and four bits per input").

predict / balanced_score: evaluate the evolved circuit.
to_verilog / to_c / hardware_report: the ASIC/FPGA toolflow (§4).
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.core import encoding as E
from repro.core import fitness as F
from repro.core import gates, hardware, netlist, verilog
from repro.core.evolve import EvolveConfig, EvolveState, evolve_packed
from repro.core.genome import CircuitSpec, Genome, opcodes

# On-disk ServableCircuit bundle format (see ServableCircuit.save):
# a single .npz holding the genome/encoder arrays plus a JSON metadata
# string.  Bump on any incompatible layout change; load() rejects
# versions it does not know.
#
# Version history:
#   1 — genome + spec + encoder + class count + validated backend.
#   2 — adds optional lineage metadata (parent content hash, refit
#       generation, shadow-window stats, promotion verdict) and the
#       fit-time per-bit activation frequencies (``enc_ref_stats``) the
#       online drift detectors baseline against.  v1 bundles still load
#       (lineage and reference stats simply absent).
SERVABLE_FORMAT_VERSION = 2
_SERVABLE_READABLE_VERSIONS = (1, 2)
SERVABLE_FORMAT_KIND = "tiny-classifier-circuits/servable-circuit"


def read_servable_meta(path: str) -> dict:
    """Read just the JSON metadata of a saved ServableCircuit bundle
    (format version, circuit spec, encoder config, validating backend)."""
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["meta"]))


@dataclasses.dataclass
class FitRecord:
    encoding: E.EncodingConfig
    val_fitness: float
    train_fitness: float
    generations: int


DEFAULT_ENCODINGS = (
    E.EncodingConfig("quantize", 2),
    E.EncodingConfig("quantize", 4),
    E.EncodingConfig("quantile", 2),
    E.EncodingConfig("quantile", 4),
)


def decode_predictions(
    out_words, n_rows: int, n_classes: int
) -> np.ndarray:
    """Packed circuit output words → int class ids, length exactly n_rows.

    `pack_bits_rows` pads the row axis up to the 32-bit word boundary; the
    circuit computes garbage bits for those pad rows, so the decode must trim
    to the true row count before the class clamp (out-of-range binary codes
    map to the last class, matching training-time fitness masking).

    Pure numpy on purpose: this runs on the host per tenant per serving
    tick with a request-dependent ``n_rows``, and a jnp decode would jit
    a fresh set of kernels for every new row count (measured: ~0.5 s per
    novel tick shape — fatal for a deadline scheduler)."""
    words = np.asarray(out_words)                       # u32[O, W]
    shifts = np.arange(E.WORD, dtype=np.uint32)
    bits = (words[..., None] >> shifts) & np.uint32(1)  # (O, W, 32)
    bits = bits.reshape(words.shape[0], -1)[:, :n_rows].astype(np.int64)
    weights = (np.int64(1) << np.arange(words.shape[0], dtype=np.int64))
    ids = (bits * weights[:, None]).sum(axis=0)
    return np.minimum(ids, n_classes - 1)


@dataclasses.dataclass(frozen=True)
class ServableCircuit:
    """Deployable inference artifact of a fitted classifier: the evolved
    genome plus everything needed to run it on raw float features (fitted
    encoder, class count).  This is what `repro.serve.circuits` registers —
    fitting state (records, search config) deliberately stays behind.
    """

    spec: CircuitSpec
    genome: Genome
    encoder: E.Encoder
    n_classes: int
    # -- format v2 provenance (optional, excluded from equality) -------
    # lineage: who this circuit descends from and how it got promoted —
    # JSON-serializable dict with keys like ``parent_hash`` (content hash
    # of the circuit it was refit from), ``refit_generation`` (how many
    # online refits deep this line is), ``shadow`` (the shadow-window
    # stats the promotion decision saw) and ``verdict``.  None for
    # offline fits and v1 bundles.
    lineage: "dict | None" = dataclasses.field(default=None, compare=False)
    # ref_stats: fit-time per-bit activation frequencies of the encoded
    # training data (f32[n_bits_total]) — the reference snapshot the
    # serving stack's drift detectors compare live traffic against.
    ref_stats: "np.ndarray | None" = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def __post_init__(self):
        assert self.spec.n_inputs == self.encoder.n_bits_total, (
            self.spec.n_inputs, self.encoder.n_bits_total,
        )
        assert self.n_classes >= 2
        if self.ref_stats is not None:
            assert np.shape(self.ref_stats) == (self.encoder.n_bits_total,), (
                np.shape(self.ref_stats), self.encoder.n_bits_total,
            )

    @property
    def n_inputs(self) -> int:
        return self.spec.n_inputs

    @property
    def n_outputs(self) -> int:
        return self.spec.n_outputs

    def predict(
        self, x: np.ndarray, *,
        backend: "str | runtime.EvalBackend" = "ref",
    ) -> np.ndarray:
        """Single-model reference path (the serving engine must match this
        bit-exactly)."""
        be = runtime.resolve_backend(backend)
        bits = E.encode(self.encoder, np.asarray(x, np.float32))
        r = bits.shape[0]
        x_words = E.pack_bits_rows(bits, E.n_words(r))
        out = be.eval_circuit(
            opcodes(self.genome, self.spec),
            self.genome.edge_src,
            self.genome.out_src,
            jnp.asarray(x_words),
        )
        return decode_predictions(out, r, self.n_classes)

    def serve_async(
        self, *,
        backend: "str | runtime.EvalBackend" = "ref",
        tenant: str = "default",
        qos=None,
        clock=None,
    ):
        """One-call async serving of this artifact.

        Builds a single-tenant `CircuitRegistry` + `CircuitServer` and
        returns an (unstarted) `AsyncCircuitServer`; enter it to run the
        deadline scheduler::

            with sc.serve_async() as frontend:
                fut = frontend.enqueue("default", x, deadline_s=0.05)
                ids = fut.result()

        or from a coroutine::

            async with sc.serve_async() as frontend:
                ids = await frontend.submit("default", x)

        ``qos`` optionally pins the tenant's `TenantQoS`; ``clock``
        injects a time source (tests).  More tenants can be added to
        ``frontend.server.registry`` afterwards — this is a convenience
        entry, not a constraint."""
        from repro.serve.async_frontend import AsyncCircuitServer
        from repro.serve.circuits import CircuitRegistry, CircuitServer

        reg = CircuitRegistry()
        reg.add(tenant, self, qos=qos)
        server = CircuitServer(reg, backend=backend)
        kwargs = {} if clock is None else {"clock": clock}
        return AsyncCircuitServer(server, **kwargs)

    # -- persistence ---------------------------------------------------
    def save(
        self, path: str, *,
        validated_backend: "str | runtime.EvalBackend" = "ref",
    ) -> str:
        """Deprecated alias of `save_servable` — one more release, then
        gone.  Prefer `save_servable(sc, path)` for single bundles, or an
        `repro.serve.artifacts.ArtifactStore` for anything fleet-shaped
        (content-addressed objects, one manifest, executables)."""
        warnings.warn(
            "ServableCircuit.save() is deprecated; use "
            "repro.core.api.save_servable(circuit, path) or an "
            "repro.serve.artifacts.ArtifactStore",
            DeprecationWarning, stacklevel=2,
        )
        return save_servable(self, path, validated_backend=validated_backend)

    @classmethod
    def load(cls, path: str) -> "ServableCircuit":
        """Deprecated alias of `load_servable` — one more release, then
        gone."""
        warnings.warn(
            "ServableCircuit.load() is deprecated; use "
            "repro.core.api.load_servable(path) or an "
            "repro.serve.artifacts.ArtifactStore",
            DeprecationWarning, stacklevel=2,
        )
        return load_servable(path)


def save_servable(
    circuit: ServableCircuit, path: str, *,
    validated_backend: "str | runtime.EvalBackend" = "ref",
) -> str:
    """Write a `ServableCircuit` as a versioned npz+JSON bundle.

    The bundle carries everything `load_servable` needs to serve raw
    float features — genome arrays, circuit spec (incl. the opcode
    function set), fitted encoder parameters, class count — plus a
    format version and the name of the backend the artifact was
    validated on.  Returns the path written (np.savez appends ``.npz``
    when missing).  This is the one canonical bundle writer; the
    registry/fleet persistence layers (`repro.serve.artifacts`) delegate
    here so every circuit on disk shares one format.
    """
    be_name = runtime.resolve_backend(validated_backend).name
    meta = {
        "kind": SERVABLE_FORMAT_KIND,
        "format_version": SERVABLE_FORMAT_VERSION,
        "spec": {
            "n_inputs": int(circuit.spec.n_inputs),
            "n_nodes": int(circuit.spec.n_nodes),
            "n_outputs": int(circuit.spec.n_outputs),
            "fn_set": [int(op) for op in circuit.spec.fn_set],
        },
        "encoder": {
            "strategy": circuit.encoder.strategy,
            "bits": int(circuit.encoder.bits),
        },
        "n_classes": int(circuit.n_classes),
        "validated_backend": be_name,
        # v2: lineage rides the JSON (it is metadata, not tensors);
        # json.dumps raises here — not at load — if a caller sneaks
        # in something non-serializable
        "lineage": circuit.lineage,
    }
    if not path.endswith(".npz"):
        path = path + ".npz"
    arrays = {
        "gate_fn": np.asarray(circuit.genome.gate_fn, np.int32),
        "edge_src": np.asarray(circuit.genome.edge_src, np.int32),
        "out_src": np.asarray(circuit.genome.out_src, np.int32),
        "enc_thresholds": np.asarray(circuit.encoder.thresholds, np.float32),
        "enc_codes": np.asarray(circuit.encoder.codes, np.uint8),
    }
    if circuit.ref_stats is not None:
        arrays["enc_ref_stats"] = np.asarray(circuit.ref_stats, np.float32)
    np.savez(path, meta=json.dumps(meta), **arrays)
    return path


def load_servable(path: str) -> ServableCircuit:
    """Load a bundle written by `save_servable`; predictions are
    bit-identical to the artifact that was saved."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
        if meta.get("kind") != SERVABLE_FORMAT_KIND:
            raise ValueError(
                f"{path}: not a ServableCircuit bundle "
                f"(kind={meta.get('kind')!r})"
            )
        version = meta.get("format_version")
        if version not in _SERVABLE_READABLE_VERSIONS:
            raise ValueError(
                f"{path}: unsupported bundle format version {version!r} "
                f"(this build reads versions "
                f"{list(_SERVABLE_READABLE_VERSIONS)})"
            )
        spec = CircuitSpec(
            n_inputs=meta["spec"]["n_inputs"],
            n_nodes=meta["spec"]["n_nodes"],
            n_outputs=meta["spec"]["n_outputs"],
            fn_set=tuple(meta["spec"]["fn_set"]),
        )
        genome = Genome(
            gate_fn=jnp.asarray(z["gate_fn"], jnp.int32),
            edge_src=jnp.asarray(z["edge_src"], jnp.int32),
            out_src=jnp.asarray(z["out_src"], jnp.int32),
        )
        encoder = E.Encoder(
            thresholds=np.asarray(z["enc_thresholds"], np.float32),
            codes=np.asarray(z["enc_codes"], np.uint8),
            strategy=meta["encoder"]["strategy"],
            bits=meta["encoder"]["bits"],
        )
        # v2 additions; absent from v1 bundles (and optional in v2)
        ref_stats = (
            np.asarray(z["enc_ref_stats"], np.float32)
            if "enc_ref_stats" in z.files else None
        )
    return ServableCircuit(
        spec=spec, genome=genome, encoder=encoder,
        n_classes=meta["n_classes"],
        lineage=meta.get("lineage"),
        ref_stats=ref_stats,
    )


class AutoTinyClassifier:
    def __init__(
        self,
        n_gates: int = 300,
        fn_set: str | tuple[int, ...] = "full",
        encodings: Sequence[E.EncodingConfig] = DEFAULT_ENCODINGS,
        lam: int = 4,
        p: float | None = None,
        gamma: float = 0.01,
        kappa: int = 300,
        max_gens: int = 8000,
        n_out_bits: int | None = None,
        val_fraction: float = 0.5,
        seed: int = 0,
        backend: "str | runtime.EvalBackend" = "ref",
    ):
        self.backend = runtime.resolve_backend(backend)
        self.fn_set = gates.FUNCTION_SETS[fn_set] if isinstance(fn_set, str) else fn_set
        self.n_gates = n_gates
        self.encodings = tuple(encodings)
        self.cfg = EvolveConfig(
            lam=lam, p=p, gamma=gamma, kappa=kappa, max_gens=max_gens,
            backend=self.backend,
        )
        self.n_out_bits = n_out_bits
        self.val_fraction = val_fraction
        self.seed = seed
        # fitted state
        self.spec_: CircuitSpec | None = None
        self.genome_: Genome | None = None
        self.encoder_: E.Encoder | None = None
        self.n_classes_: int | None = None
        self.ref_stats_: np.ndarray | None = None
        self.records_: list[FitRecord] = []

    # ------------------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray, n_classes: int | None = None):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.int64)
        self.n_classes_ = n_classes or int(y.max()) + 1
        n_out = self.n_out_bits or max(
            1, int(np.ceil(np.log2(max(self.n_classes_, 2))))
        )
        best = None
        self.records_ = []
        for ei, ecfg in enumerate(self.encodings):
            enc = E.fit_encoder(x, ecfg)
            bits = E.encode(enc, x)
            data = E.pack_dataset(bits, y, self.n_classes_, n_out)
            w = data.x_words.shape[1]
            mtr, mva = E.split_masks(
                x.shape[0], w, self.val_fraction, seed=self.seed + ei
            )
            spec = CircuitSpec(
                n_inputs=bits.shape[1], n_nodes=self.n_gates,
                n_outputs=n_out, fn_set=self.fn_set,
            )
            key = jax.random.key(self.seed * 1000 + ei)
            final: EvolveState = evolve_packed(key, spec, self.cfg, data, mtr, mva)
            rec = FitRecord(
                encoding=ecfg,
                val_fitness=float(final.best_val),
                train_fitness=float(final.best_train),
                generations=int(final.gen),
            )
            self.records_.append(rec)
            if best is None or rec.val_fitness > best[0]:
                # per-bit activation frequency of the encoded training
                # data: the reference snapshot online drift detection
                # compares live traffic against (bundle v2 `ref_stats`)
                best = (rec.val_fitness, spec, final.best, enc,
                        bits.mean(axis=0).astype(np.float32))
        (_, self.spec_, self.genome_, self.encoder_,
         self.ref_stats_) = best
        return self

    # ------------------------------------------------------------------
    def _require_fit(self):
        if self.genome_ is None:
            raise RuntimeError("call fit() first")

    def to_servable(self) -> ServableCircuit:
        """Export the deployment artifact (registered by serve.circuits)."""
        self._require_fit()
        return ServableCircuit(
            spec=self.spec_, genome=self.genome_,
            encoder=self.encoder_, n_classes=self.n_classes_,
            ref_stats=self.ref_stats_,
        )

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.to_servable().predict(x, backend=self.backend)

    def balanced_score(self, x: np.ndarray, y: np.ndarray) -> float:
        pred = self.predict(x)
        y = np.asarray(y)
        return F.balanced_accuracy_rows(
            pred, y, np.ones_like(y, bool), self.n_classes_
        )

    def accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        return float((self.predict(x) == np.asarray(y)).mean())

    # ------------------------------------------------------------------
    def netlist(self) -> netlist.Netlist:
        self._require_fit()
        return netlist.extract(self.genome_, self.spec_)

    def to_verilog(self, module_name: str = "tiny_classifier",
                   registered: bool = False) -> str:
        return verilog.to_verilog(self.netlist(), module_name, registered)

    def to_c(self, fn_name: str = "tiny_classifier_predict") -> str:
        return verilog.to_c(self.netlist(), fn_name)

    def hardware_report(
        self, tech: hardware.TechModel = hardware.SILICON_45NM,
        design: str = "tiny",
    ) -> hardware.HardwareReport:
        return hardware.tiny_classifier_report(self.netlist(), tech, design)
