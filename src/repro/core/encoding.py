"""Feature → bit encoders and bit-packing (paper §5.2, §4.1).

Encoding strategies (paper names):
  * ``quantize``  — equal-width buckets, binary code
  * ``quantile``  — equal-frequency buckets, binary code
  * ``gray``      — equal-width buckets, Gray code
  * ``onehot``    — equal-frequency buckets, one-hot code (bits == buckets)

``bits`` is the user-tunable *bits per input* (paper evaluates 2 and 4).
Binary/Gray use 2**bits buckets; one-hot uses ``bits`` buckets.

Packing layout (DESIGN.md §3.1): dataset rows are packed 32/``uint32`` word.
``x_words[b, w]`` bit ``j`` is the value of encoded input bit ``b`` for row
``32*w + j``.  Fitness then reduces with ``lax.population_count`` and is
exactly invariant to sharding the word axis (psum of confusion counts).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

STRATEGIES = ("quantize", "quantile", "gray", "onehot")


@dataclasses.dataclass(frozen=True)
class EncodingConfig:
    strategy: str = "quantize"
    bits: int = 2

    def __post_init__(self):
        assert self.strategy in STRATEGIES, self.strategy
        assert 1 <= self.bits <= 8

    @property
    def n_buckets(self) -> int:
        return self.bits if self.strategy == "onehot" else 2 ** self.bits


class Encoder(NamedTuple):
    """Fitted per-feature thresholds + code table (host numpy)."""

    thresholds: np.ndarray  # float32[F, n_buckets-1], ascending per feature
    codes: np.ndarray       # uint8[n_buckets, bits]
    strategy: str
    bits: int

    @property
    def n_features(self) -> int:
        return self.thresholds.shape[0]

    @property
    def n_bits_total(self) -> int:
        return self.n_features * self.bits


def _gray(i: int) -> int:
    return i ^ (i >> 1)


def _code_table(cfg: EncodingConfig) -> np.ndarray:
    nb, bits = cfg.n_buckets, cfg.bits
    table = np.zeros((nb, bits), dtype=np.uint8)
    for i in range(nb):
        if cfg.strategy == "onehot":
            table[i, i] = 1
        else:
            v = _gray(i) if cfg.strategy == "gray" else i
            for b in range(bits):
                table[i, b] = (v >> b) & 1
    return table


def fit_encoder(x_train: np.ndarray, cfg: EncodingConfig) -> Encoder:
    """Fit per-feature bucket thresholds on training data only."""
    x = np.asarray(x_train, dtype=np.float64)
    assert x.ndim == 2
    nb = cfg.n_buckets
    if cfg.strategy in ("quantize", "gray"):
        lo, hi = x.min(axis=0), x.max(axis=0)
        span = np.where(hi > lo, hi - lo, 1.0)
        edges = lo[:, None] + span[:, None] * (np.arange(1, nb) / nb)[None, :]
    else:  # equal-frequency
        qs = np.quantile(x, np.arange(1, nb) / nb, axis=0).T  # (F, nb-1)
        edges = qs
    # strictly non-decreasing thresholds per feature
    edges = np.maximum.accumulate(edges, axis=1)
    return Encoder(edges.astype(np.float32), _code_table(cfg), cfg.strategy, cfg.bits)


def encode(enc: Encoder, x: np.ndarray) -> np.ndarray:
    """Encode raw features → bit matrix uint8[R, F*bits]."""
    x = np.asarray(x, dtype=np.float32)
    r, f = x.shape
    assert f == enc.n_features
    buckets = np.empty((r, f), dtype=np.int64)
    for j in range(f):
        buckets[:, j] = np.searchsorted(enc.thresholds[j], x[:, j], side="right")
    bits = enc.codes[buckets]                 # (R, F, bits)
    return bits.reshape(r, f * enc.bits).astype(np.uint8)


def encode_batched(
    enc: Encoder, arrays: "list[np.ndarray]"
) -> tuple[np.ndarray, np.ndarray]:
    """Encode several row blocks through one vectorized `encode` call.

    The serving micro-batcher concatenates a tenant's pending request rows,
    encodes them in a single searchsorted sweep per feature, and splits the
    results back by offset.  Returns (bits uint8[R_total, F*bits],
    offsets int64[len(arrays)+1]) with block k at rows
    [offsets[k], offsets[k+1]).
    """
    arrays = [np.asarray(a, np.float32) for a in arrays]
    offsets = np.zeros(len(arrays) + 1, np.int64)
    if arrays:
        offsets[1:] = np.cumsum([a.shape[0] for a in arrays])
    if not arrays or offsets[-1] == 0:
        return np.zeros((0, enc.n_bits_total), np.uint8), offsets
    bits = encode(enc, np.concatenate(arrays, axis=0))
    return bits, offsets


def class_code_bits(n_classes: int, n_out_bits: int | None = None) -> np.ndarray:
    """Binary class codes uint8[C, O] (paper §3.6: outputs encode the class)."""
    o = n_out_bits or max(1, int(np.ceil(np.log2(max(n_classes, 2)))))
    assert 2 ** o >= n_classes, (o, n_classes)
    table = np.zeros((n_classes, o), dtype=np.uint8)
    for c in range(n_classes):
        for b in range(o):
            table[c, b] = (c >> b) & 1
    return table


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------

WORD = 32


def n_words(n_rows: int, pad_to: int = 1) -> int:
    w = (n_rows + WORD - 1) // WORD
    return ((w + pad_to - 1) // pad_to) * pad_to


class PackedDataset(NamedTuple):
    """Bit-packed dataset; all arrays share the word axis W (shardable)."""

    x_words: jax.Array      # uint32[I, W] encoded input bits
    y_words: jax.Array      # uint32[O, W] class-code bits of the label
    class_words: jax.Array  # uint32[C, W] row mask per class (y == c)
    mask_words: jax.Array   # uint32[W]    valid (non-padding) rows

    @property
    def n_inputs(self) -> int:
        return self.x_words.shape[0]

    @property
    def n_outputs(self) -> int:
        return self.y_words.shape[0]

    @property
    def n_classes(self) -> int:
        return self.class_words.shape[0]


def pack_bits_rows(bits: np.ndarray, w: int) -> np.ndarray:
    """uint8[R, B] {0,1} → uint32[B, w] packed along rows."""
    r, b = bits.shape
    pad = w * WORD - r
    assert pad >= 0
    x = np.concatenate([bits, np.zeros((pad, b), np.uint8)], axis=0)
    x = x.T.reshape(b, w, WORD).astype(np.uint32)
    return (x << np.arange(WORD, dtype=np.uint32)[None, None, :]).sum(
        axis=-1, dtype=np.uint32
    )


def unpack_words(words: jax.Array, n_rows: int) -> jax.Array:
    """uint32[…, W] → uint8[…, n_rows] (jnp; inverse of pack_bits_rows)."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(*words.shape[:-1], -1)
    return flat[..., :n_rows].astype(jnp.uint8)


def pack_dataset(
    bits: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    n_out_bits: int | None = None,
    pad_words_to: int = 1,
) -> PackedDataset:
    """Pack an encoded bit matrix + labels into a PackedDataset.

    pad_words_to: round W up (e.g. to 128·k lanes for the Pallas kernel, or to
    the data-shard count for distributed fitness).
    """
    r = bits.shape[0]
    y = np.asarray(y, dtype=np.int64)
    assert y.shape == (r,)
    w = n_words(r, pad_words_to)
    codes = class_code_bits(n_classes, n_out_bits)        # (C, O)
    y_bits = codes[y]                                     # (R, O)
    cls_bits = (y[:, None] == np.arange(n_classes)[None, :]).astype(np.uint8)
    mask_bits = np.ones((r, 1), dtype=np.uint8)
    return PackedDataset(
        x_words=jnp.asarray(pack_bits_rows(bits, w)),
        y_words=jnp.asarray(pack_bits_rows(y_bits, w)),
        class_words=jnp.asarray(pack_bits_rows(cls_bits, w)),
        mask_words=jnp.asarray(pack_bits_rows(mask_bits, w)[0]),
    )


def split_masks(
    n_rows: int, w: int, val_fraction: float, seed: int
) -> tuple[jax.Array, jax.Array]:
    """Random row-level train/val masks as packed words (paper §3.3: 50/50
    split by default; fitness on train selects, fitness on val picks the
    best-discovered solution)."""
    rng = np.random.RandomState(seed)
    is_val = rng.rand(n_rows) < val_fraction
    tr = (~is_val)[:, None].astype(np.uint8)
    va = is_val[:, None].astype(np.uint8)
    return (
        jnp.asarray(pack_bits_rows(tr, w)[0]),
        jnp.asarray(pack_bits_rows(va, w)[0]),
    )
