"""RTL emission (paper §4.1: "the sea of gates is automatically translated
into RTL, typically as multiple Verilog assign statements per output bit")
plus the C emission used by the FPGA/HLS flow (§4.2).

Also includes a miniature simulator for the *emitted Verilog text* so tests
can close the loop: JAX eval == netlist interpreter == emitted RTL.
"""
from __future__ import annotations

import re

import numpy as np

from repro.core import gates
from repro.core.netlist import Netlist


def _sig(net: Netlist, sid: int) -> str:
    return f"x[{sid}]" if sid < net.n_inputs else f"n{sid}"


def to_verilog(net: Netlist, module_name: str = "tiny_classifier",
               registered: bool = False) -> str:
    """Emit the classifier as a Verilog module.

    registered=True wraps the combinational sea of gates with the paper's
    input/output buffers (§3.6) — DFFs on the *used* input bits and outputs.
    """
    lines = []
    if registered:
        lines.append(f"module {module_name} (")
        lines.append("  input  wire clk,")
        lines.append(f"  input  wire [{net.n_inputs - 1}:0] x_in,")
        lines.append(f"  output reg  [{net.n_outputs - 1}:0] y")
        lines.append(");")
        lines.append(f"  reg [{net.n_inputs - 1}:0] x;")
        used = ", ".join(str(i) for i in net.used_inputs)
        lines.append(f"  // input buffer holds only consumed bits: [{used}]")
        lines.append("  always @(posedge clk) begin")
        for i in net.used_inputs:
            lines.append(f"    x[{i}] <= x_in[{i}];")
        lines.append("  end")
    else:
        lines.append(f"module {module_name} (")
        lines.append(f"  input  wire [{net.n_inputs - 1}:0] x,")
        lines.append(f"  output wire [{net.n_outputs - 1}:0] y")
        lines.append(");")

    for node in net.nodes:
        a = _sig(net, node.srcs[0])
        b = _sig(net, node.srcs[1]) if len(node.srcs) > 1 else a
        expr = gates.VERILOG_EXPR[node.opcode].format(a=a, b=b)
        lines.append(f"  wire n{node.nid};")
        lines.append(f"  assign n{node.nid} = {expr};")

    if registered:
        lines.append("  always @(posedge clk) begin")
        for o, s in enumerate(net.out_src):
            lines.append(f"    y[{o}] <= {_sig(net, s)};")
        lines.append("  end")
    else:
        for o, s in enumerate(net.out_src):
            lines.append(f"  assign y[{o}] = {_sig(net, s)};")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"


def to_c(net: Netlist, fn_name: str = "tiny_classifier_predict") -> str:
    """Emit the HLS-ready C function (paper §4.2 Composer input)."""
    lines = [
        "#include <stdint.h>",
        "",
        f"void {fn_name}(const uint8_t x[{net.n_inputs}], "
        f"uint8_t y[{net.n_outputs}]) {{",
        "#pragma HLS PIPELINE II=1",
    ]

    def sig(sid: int) -> str:
        return f"x[{sid}]" if sid < net.n_inputs else f"n{sid}"

    for node in net.nodes:
        a = sig(node.srcs[0])
        b = sig(node.srcs[1]) if len(node.srcs) > 1 else a
        expr = gates.C_EXPR[node.opcode].format(a=a, b=b)
        lines.append(f"  uint8_t n{node.nid} = (uint8_t){expr} & 1u;")
    for o, s in enumerate(net.out_src):
        lines.append(f"  y[{o}] = {sig(s)} & 1u;")
    lines.append("}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Emitted-Verilog simulator (tests the *text*, not the netlist object)
# ---------------------------------------------------------------------------

_ASSIGN_RE = re.compile(r"assign\s+(\S+)\s*=\s*(.+);")


def simulate_verilog(verilog: str, x_bits: np.ndarray) -> np.ndarray:
    """Evaluate a combinational module emitted by :func:`to_verilog` on a
    batch of input vectors.  uint8[R, I] → uint8[R, O]."""
    x_bits = np.asarray(x_bits, dtype=np.uint8)
    r = x_bits.shape[0]
    env: dict[str, np.ndarray] = {}
    n_out = 0
    outputs: dict[int, np.ndarray] = {}

    def term(tok: str) -> np.ndarray:
        tok = tok.strip()
        m = re.fullmatch(r"x\[(\d+)\]", tok)
        if m:
            return x_bits[:, int(m.group(1))]
        return env[tok]

    def eval_expr(expr: str) -> np.ndarray:
        expr = expr.strip()
        neg = False
        while expr.startswith("~"):
            neg = not neg
            expr = expr[1:].strip()
        if expr.startswith("("):
            assert expr.endswith(")"), expr
            inner = expr[1:-1]
            for opch, fn in (
                ("&", lambda a, b: a & b),
                ("|", lambda a, b: a | b),
                ("^", lambda a, b: a ^ b),
            ):
                # split at top level (our emission has no nested parens)
                if opch in inner:
                    a, b = inner.split(opch, 1)
                    v = fn(term(a), term(b))
                    break
            else:
                v = term(inner)
        else:
            v = term(expr)
        return (1 - v).astype(np.uint8) if neg else v.astype(np.uint8)

    for line in verilog.splitlines():
        m = _ASSIGN_RE.search(line)
        if not m:
            continue
        lhs, rhs = m.group(1), m.group(2)
        ym = re.fullmatch(r"y\[(\d+)\]", lhs)
        if ym:
            o = int(ym.group(1))
            outputs[o] = eval_expr(rhs)
            n_out = max(n_out, o + 1)
        else:
            env[lhs] = eval_expr(rhs)

    out = np.zeros((r, n_out), dtype=np.uint8)
    for o, v in outputs.items():
        out[:, o] = v
    return out
