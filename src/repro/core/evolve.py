"""The 1+λ evolutionary loop with neutral drift (paper §3).

Selection uses ``>=`` (a child with *equal* training fitness replaces the
parent) — the neutral-drift random walk over equivalent solutions that lets
the search escape local optima (paper §3, Kimura's neutral theory).

Best-solution tracking and termination follow §3.3–3.4:
  * training fitness selects the next parent;
  * validation fitness picks the best-discovered solution;
  * terminate when validation fitness has not improved by ≥ γ within κ
    generations, or after G generations.

Hyper-parameter defaults are the paper's: λ=4, p=1/n, γ=0.01 (§3.5); the
evaluation settings n=300 gates, κ=300, G=8000 (§5.4) live in configs.

Fitness evaluation is *batched over the population* (λ children evaluated in
one pass) so the same code path drives the pure-jnp oracle, the Pallas
kernel, and the shard_map'd distributed islands (repro.core.islands).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import runtime
from repro.core import fitness as F
from repro.core.encoding import PackedDataset
from repro.core.genome import CircuitSpec, Genome, init_genome, opcodes
from repro.core.mutate import mutate_children

# Batched eval: stacked genomes (leading λ axis) → (train_fits, val_fits).
BatchEvalFn = Callable[[Genome], tuple[jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class EvolveConfig:
    lam: int = 4
    p: float | None = None   # mutation rate; None → 1/n (paper §3.5)
    gamma: float = 0.01
    kappa: int = 300
    max_gens: int = 8000
    # execution backend for fitness eval (name or repro.runtime.EvalBackend)
    backend: "str | runtime.EvalBackend" = "ref"

    def rate(self, spec: CircuitSpec) -> float:
        return self.p if self.p is not None else 1.0 / spec.n_nodes


class EvolveState(NamedTuple):
    key: jax.Array
    parent: Genome
    parent_fit: jax.Array   # f32 training fitness of S
    best: Genome            # best-discovered solution (by validation fitness)
    best_val: jax.Array     # f32
    best_train: jax.Array   # f32 training fitness of `best` (reporting)
    ref_val: jax.Array      # γ-improvement reference (§3.4)
    since: jax.Array        # generations since the last ≥γ val improvement
    gen: jax.Array          # generation counter


def make_eval_fn(
    spec: CircuitSpec,
    data: PackedDataset,
    mask_train: jax.Array,
    mask_val: jax.Array,
    backend: "str | runtime.EvalBackend" = "ref",
) -> BatchEvalFn:
    """Single forward pass over *all* packed rows; train and val fitness are
    two masked confusion reductions over the same circuit outputs."""
    be = runtime.resolve_backend(backend)

    def eval_fn(genomes: Genome):
        out = be.eval_population(
            opcodes(genomes, spec), genomes.edge_src, genomes.out_src,
            data.x_words,
        )  # (λ, O, W)
        ft = jax.vmap(lambda o: F.balanced_accuracy(o, data, mask_train))(out)
        fv = jax.vmap(lambda o: F.balanced_accuracy(o, data, mask_val))(out)
        return ft, fv

    return eval_fn


def _stack1(genome: Genome) -> Genome:
    return jax.tree.map(lambda x: x[None], genome)


def _select(key, fits: jax.Array) -> jax.Array:
    """argmax with uniform tie-breaking (paper §3: ties at random)."""
    m = fits.max()
    u = jax.random.uniform(key, fits.shape)
    return jnp.argmax(jnp.where(fits == m, u, -1.0))


def init_state(
    key: jax.Array,
    spec: CircuitSpec,
    eval_fn: BatchEvalFn,
    seed_genome: "Genome | None" = None,
) -> EvolveState:
    """Initial 1+λ state.  ``seed_genome`` (when given) becomes the first
    parent instead of a random genome — the warm-start used by online
    refits that continue evolving a circuit already serving traffic."""
    k_init, key = jax.random.split(key)
    parent = init_genome(k_init, spec) if seed_genome is None else seed_genome
    ft, fv = eval_fn(_stack1(parent))
    zero = jnp.zeros((), jnp.int32)
    return EvolveState(
        key=key, parent=parent, parent_fit=ft[0],
        best=parent, best_val=fv[0], best_train=ft[0],
        ref_val=fv[0], since=zero, gen=zero,
    )


def generation_step(
    state: EvolveState, spec: CircuitSpec, cfg: EvolveConfig, eval_fn: BatchEvalFn
) -> EvolveState:
    key, k_mut, k_sel = jax.random.split(state.key, 3)
    children = mutate_children(k_mut, state.parent, spec, cfg.rate(spec), cfg.lam)
    ft, fv = eval_fn(children)  # (λ,), (λ,)

    # --- parent replacement: any child with f_i >= f_S; highest wins ---
    sel = _select(k_sel, ft)
    accept = ft[sel] >= state.parent_fit
    parent = jax.tree.map(
        lambda c, p: jnp.where(accept, c[sel], p), children, state.parent
    )
    parent_fit = jnp.where(accept, ft[sel], state.parent_fit)

    # --- best-discovered solution by validation fitness ---
    bidx = jnp.argmax(fv)
    improved = fv[bidx] > state.best_val
    best = jax.tree.map(
        lambda c, b: jnp.where(improved, c[bidx], b), children, state.best
    )
    best_val = jnp.maximum(state.best_val, fv[bidx])
    best_train = jnp.where(improved, ft[bidx], state.best_train)

    # --- γ/κ termination bookkeeping ---
    big_improve = best_val >= state.ref_val + cfg.gamma
    ref_val = jnp.where(big_improve, best_val, state.ref_val)
    since = jnp.where(big_improve, 0, state.since + 1)

    return EvolveState(
        key=key, parent=parent, parent_fit=parent_fit,
        best=best, best_val=best_val, best_train=best_train,
        ref_val=ref_val, since=since, gen=state.gen + 1,
    )


def not_terminated(state: EvolveState, cfg: EvolveConfig) -> jax.Array:
    return (state.gen < cfg.max_gens) & (state.since < cfg.kappa)


def evolve(
    key: jax.Array, spec: CircuitSpec, cfg: EvolveConfig, eval_fn: BatchEvalFn,
    seed_genome: "Genome | None" = None,
) -> EvolveState:
    """Run to termination (lax.while_loop — early exit, no history)."""
    state = init_state(key, spec, eval_fn, seed_genome=seed_genome)
    return jax.lax.while_loop(
        lambda s: not_terminated(s, cfg),
        lambda s: generation_step(s, spec, cfg, eval_fn),
        state,
    )


def evolve_with_history(
    key: jax.Array, spec: CircuitSpec, cfg: EvolveConfig, eval_fn: BatchEvalFn
):
    """Fixed-length scan variant recording per-generation curves (used by the
    Fig. 8 benchmarks).  Terminated states pass through unchanged."""
    state = init_state(key, spec, eval_fn)

    def body(s, _):
        live = not_terminated(s, cfg)
        s2 = generation_step(s, spec, cfg, eval_fn)
        s = jax.tree.map(lambda a, b: jnp.where(live, a, b), s2, s)
        return s, (s.parent_fit, s.best_val, live)

    final, hist = jax.lax.scan(body, state, None, length=cfg.max_gens)
    return final, hist


def evolve_packed(
    key: jax.Array,
    spec: CircuitSpec,
    cfg: EvolveConfig,
    data: PackedDataset,
    mask_train: jax.Array,
    mask_val: jax.Array,
    seed_genome: "Genome | None" = None,
) -> EvolveState:
    """Convenience: evolve directly on a PackedDataset.  ``seed_genome``
    warm-starts the search from an existing circuit (online refit)."""
    eval_fn = make_eval_fn(spec, data, mask_train, mask_val, cfg.backend)
    return evolve(key, spec, cfg, eval_fn, seed_genome=seed_genome)
