"""Circuit genome representation for EGGP-style evolution (paper §3.1).

A genome is a feed-forward sea-of-gates graph:

  * ``I`` input nodes (ids ``0 … I-1``)   — one per encoded feature bit,
  * ``n`` function nodes (ids ``I … I+n-1``) — each with an opcode and two
    operand edges,
  * ``O`` output nodes — each tapping any input/function node.

Acyclicity: node ``i`` may only read ids ``< I + i`` (topological index
space — the JAX-native adaptation of EGGP's explicit cycle check; see
DESIGN.md §3.3: the representable function space is unchanged, only the
mutation neighbourhood differs).

Genomes are pytrees of arrays so they vmap/scan/shard transparently:
population axes, island axes and sweep axes are all plain leading dims.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CircuitSpec:
    """Static description of the genome search space."""

    n_inputs: int
    n_nodes: int
    n_outputs: int
    fn_set: tuple[int, ...] = (0, 1, 2, 3)  # opcodes (gates.FULL_FS default)

    def __post_init__(self):
        assert self.n_inputs >= 1 and self.n_nodes >= 1 and self.n_outputs >= 1
        assert len(self.fn_set) >= 1

    @property
    def n_edges(self) -> int:
        """Total mutable edges E = 2n function-node edges + O output taps."""
        return 2 * self.n_nodes + self.n_outputs

    @property
    def total_ids(self) -> int:
        return self.n_inputs + self.n_nodes

    def fn_table(self):
        return jnp.asarray(self.fn_set, dtype=jnp.int32)


class Genome(NamedTuple):
    """Pytree of genome arrays.  ``gate_fn`` stores *indices into
    spec.fn_set* (not raw opcodes) so node mutation can sample uniformly from
    F \\ {current} with modular arithmetic."""

    gate_fn: jax.Array   # int32[n]     index into spec.fn_set
    edge_src: jax.Array  # int32[n, 2]  operand ids, edge_src[i] in [0, I+i)
    out_src: jax.Array   # int32[O]     output taps in [0, I+n)

    @property
    def n_nodes(self) -> int:
        return self.gate_fn.shape[-1]


def opcodes(genome: Genome, spec: CircuitSpec) -> jax.Array:
    """Map stored fn-set indices to raw gate opcodes."""
    return spec.fn_table()[genome.gate_fn]


def init_genome(key: jax.Array, spec: CircuitSpec) -> Genome:
    """Random initialisation (paper §3.2): each node gets a uniform function
    from F and operands drawn uniformly from the ids preceding it; each output
    taps a uniform id."""
    k_fn, k_edge, k_out = jax.random.split(key, 3)
    n, im = spec.n_nodes, spec.n_inputs
    gate_fn = jax.random.randint(k_fn, (n,), 0, len(spec.fn_set), dtype=jnp.int32)
    # Valid operand range for node i is [0, I+i).
    hi = im + jnp.arange(n, dtype=jnp.int32)  # exclusive upper bound per node
    u = jax.random.uniform(k_edge, (n, 2))
    edge_src = jnp.floor(u * hi[:, None]).astype(jnp.int32)
    edge_src = jnp.minimum(edge_src, hi[:, None] - 1)
    out_src = jax.random.randint(
        k_out, (spec.n_outputs,), 0, im + n, dtype=jnp.int32
    )
    return Genome(gate_fn, edge_src, out_src)


def genome_shape_dtypes(spec: CircuitSpec) -> Genome:
    """ShapeDtypeStruct stand-in (for dry-run lowering)."""
    sds = jax.ShapeDtypeStruct
    return Genome(
        gate_fn=sds((spec.n_nodes,), jnp.int32),
        edge_src=sds((spec.n_nodes, 2), jnp.int32),
        out_src=sds((spec.n_outputs,), jnp.int32),
    )


def validate_genome(genome: Genome, spec: CircuitSpec) -> bool:
    """Host-side structural validation (used by property tests)."""
    g = jax.tree.map(np.asarray, genome)
    n, im, o = spec.n_nodes, spec.n_inputs, spec.n_outputs
    if g.gate_fn.shape != (n,) or g.edge_src.shape != (n, 2):
        return False
    if g.out_src.shape != (o,):
        return False
    if not ((g.gate_fn >= 0).all() and (g.gate_fn < len(spec.fn_set)).all()):
        return False
    hi = im + np.arange(n)
    if not ((g.edge_src >= 0).all() and (g.edge_src < hi[:, None]).all()):
        return False
    if not ((g.out_src >= 0).all() and (g.out_src < im + n).all()):
        return False
    return True


def active_nodes(genome: Genome, spec: CircuitSpec) -> np.ndarray:
    """Host-side mark-and-sweep of *active* function nodes (paper §3.1:
    nodes with no path to an output are semantically inert — the neutral-drift
    substrate).  Returns bool[n]."""
    g = jax.tree.map(np.asarray, genome)
    n, im = spec.n_nodes, spec.n_inputs
    active = np.zeros(n, dtype=bool)
    stack = [int(s) - im for s in g.out_src if int(s) >= im]
    while stack:
        i = stack.pop()
        if active[i]:
            continue
        active[i] = True
        for s in g.edge_src[i]:
            if int(s) >= im:
                stack.append(int(s) - im)
    return active
