"""Analytic hardware cost models (paper §5.5–5.6).

No EDA tools exist in this offline container, so area/power/timing are
GE-proportional analytic models **calibrated against the paper's own
published numbers** — each constant's provenance is recorded inline, and
EXPERIMENTS.md §Hardware validates the model by reproducing the paper's
Table 2 / Fig. 14-16 ratios.

Technologies:
  * SILICON_45NM — FreePDK45 (paper §5.5.1): NAND2 area 0.798 µm²,
    1.1 V / 1 GHz.  Power constant calibrated so Tiny Classifiers land in the
    paper's 0.04–0.97 mW band for 11–426 GE.
  * FLEXIC_08UM — PragmatIC 0.8 µm TFT (paper Table 2): 0.54 mm²/150 GE ⇒
    3.6e3 µm²/GE; 0.32 mW/150 GE ⇒ 2.1e-3 mW/GE at 3 V.
  * FPGA — LUT/FF packing model for Zynq Ultrascale+ (paper Fig. 16).

Baseline ML hardware (XGBoost comparator-tree, 2-bit MLP MAC array) uses the
same GE bookkeeping so all ratios are apples-to-apples.
"""
from __future__ import annotations

import dataclasses

from repro.core.netlist import Netlist

DFF_GE = 4.5  # scan-DFF in NAND2 equivalents (std-cell typical)


@dataclasses.dataclass(frozen=True)
class TechModel:
    name: str
    area_um2_per_ge: float
    power_mw_per_ge: float      # at reference frequency/voltage
    gate_delay_ns: float        # per logic level
    ff_overhead_ns: float       # clk→q + setup
    ref_freq_hz: float
    max_freq_hz: float          # process/clock-network ceiling


# NAND2 = 0.798 µm² in FreePDK45; 2.3 µW/GE reproduces the paper's
# 0.04–0.97 mW across 11–426 GE designs at 1 GHz / 1.1 V.
SILICON_45NM = TechModel(
    name="silicon-45nm", area_um2_per_ge=0.798, power_mw_per_ge=2.3e-3,
    gate_delay_ns=0.030, ff_overhead_ns=0.10, ref_freq_hz=1e9,
    max_freq_hz=2e9,
)

# Calibrated from the paper's Table 2 (blood: 150 GE → 0.54 mm², 0.32 mW,
# 350 kHz; led: 105 GE → 0.37 mm², 0.25 mW, 440 kHz).
FLEXIC_08UM = TechModel(
    name="flexic-0.8um", area_um2_per_ge=3.58e3, power_mw_per_ge=2.2e-3,
    gate_delay_ns=280.0, ff_overhead_ns=300.0, ref_freq_hz=350e3,
    max_freq_hz=1e6,
)

# Activity factors: power does not scale purely with area across design
# styles or processes.  Calibrated so the model reproduces the paper's
# published power-vs-area ratio gaps: on silicon the MLP/XGBoost power
# ratios sit *below* their area ratios (Fig. 14: MLP ≈ 86–118× power at
# 171–278× area; §5.5.1: XGBoost 3.9–8× power at 8–18× area), while on
# FlexIC the XGBoost power ratio sits slightly *above* the area ratio
# (Table 2: 12.9× power at 10× area for blood).
ACTIVITY = {
    "silicon-45nm": {"tiny": 1.0, "gbdt": 0.5, "mlp": 0.6},
    "flexic-0.8um": {"tiny": 1.0, "gbdt": 1.3, "mlp": 1.3},
}

# FPGA packing: a LUT4/6 absorbs ~2.5 2-input gates on average (ABC tech-map
# rule of thumb); FFs mirror the I/O buffer bits.
GATES_PER_LUT = 2.5


@dataclasses.dataclass(frozen=True)
class HardwareReport:
    design: str
    tech: str
    ge_logic: float
    ge_buffers: float
    ge_total: float
    depth: int
    area_mm2: float
    power_mw: float
    fmax_hz: float
    luts: int
    ffs: int

    def row(self) -> str:
        return (
            f"{self.design},{self.tech},{self.ge_total:.1f},{self.depth},"
            f"{self.area_mm2:.6f},{self.power_mw:.4f},{self.fmax_hz:.3e},"
            f"{self.luts},{self.ffs}"
        )


def _report(design: str, tech: TechModel, ge_logic: float, buffer_bits: int,
            depth: int, family: str = "tiny") -> HardwareReport:
    ge_buf = buffer_bits * DFF_GE
    ge = ge_logic + ge_buf
    act = ACTIVITY[tech.name][family]
    area = ge * tech.area_um2_per_ge / 1e6  # mm²
    power = ge * tech.power_mw_per_ge * act
    fmax = min(
        1e9 / (tech.ff_overhead_ns + max(depth, 1) * tech.gate_delay_ns),
        tech.max_freq_hz,
    )
    return HardwareReport(
        design=design, tech=tech.name, ge_logic=ge_logic, ge_buffers=ge_buf,
        ge_total=ge, depth=depth, area_mm2=area, power_mw=power, fmax_hz=fmax,
        luts=int(-(-ge_logic // GATES_PER_LUT)), ffs=buffer_bits,
    )


def tiny_classifier_report(net: Netlist, tech: TechModel,
                           design: str = "tiny") -> HardwareReport:
    return _report(design, tech, net.logic_ge(), net.buffer_bits(),
                   net.depth(), family="tiny")


# ---------------------------------------------------------------------------
# Baseline ML models in hardware (paper §5.5: manually designed baselines)
# ---------------------------------------------------------------------------

def gbdt_hw(n_trees: int, depth: int, n_features: int, feat_bits: int = 8,
            leaf_bits: int = 8, tech: TechModel = SILICON_45NM,
            design: str = "xgboost") -> HardwareReport:
    """Comparator-tree estimate for a boosted-tree ensemble.

    Per tree: one b-bit comparator per internal node (≈1.5 GE/bit), a
    leaf-select mux network (≈0.6 GE/bit per 2:1 stage) and a leaf-value
    table; ensemble adder + argmax across trees.  With depth 6 and 8-bit
    features this lands at ≈1.5 kGE/tree — matching the paper's blood
    XGBoost implementation (1520 GE, 1 estimator).
    """
    internal = 2 ** depth - 1
    leaves = 2 ** depth
    cmp_ge = internal * feat_bits * 1.65
    mux_ge = (leaves - 1) * leaf_bits * 0.7
    leaf_table_ge = leaves * leaf_bits * 0.3  # hardwired constants
    per_tree = cmp_ge + mux_ge + leaf_table_ge
    adder_ge = n_trees * leaf_bits * 2.0  # accumulation / argmax network
    logic = n_trees * per_tree + adder_ge
    buffers = n_features * feat_bits + max(1, (n_trees + 99) // 100)
    # critical path: comparator ripple + tree mux levels + adder tree
    path = feat_bits + depth + max(n_trees.bit_length(), 1) * (leaf_bits // 2)
    return _report(design, tech, logic, buffers, path, family="gbdt")


def mlp_hw(layer_sizes: list[int], weight_bits: int = 2, act_bits: int = 2,
           tech: TechModel = SILICON_45NM, design: str = "mlp") -> HardwareReport:
    """Fully-parallel quantized-MLP MAC-array estimate.

    A w-bit × a-bit multiplier is ≈ w·a·1.0 GE plus accumulate; with 2-bit
    weights/activations a MAC is ≈ 3 GE (multiplier ≈ LUT-sized + 8-bit
    accumulator amortised across the fan-in).  Calibrated to land the
    paper's smallest-MLP ≈ 171–278× Tiny area ratio (Fig. 15).
    """
    macs = sum(a * b for a, b in zip(layer_sizes[:-1], layer_sizes[1:]))
    neurons = sum(layer_sizes[1:])
    mac_ge = macs * (weight_bits * act_bits * 0.5 + 1.0)
    acc_ge = neurons * 8 * 1.2      # 8-bit accumulator + ReLU/quant per neuron
    logic = mac_ge + acc_ge
    buffers = layer_sizes[0] * act_bits + layer_sizes[-1] * 8
    # adder-tree depth per layer + quantize stage
    import math

    path = sum(
        max(1, math.ceil(math.log2(max(a, 2)))) + 4
        for a in layer_sizes[:-1]
    )
    return _report(design, tech, logic, buffers, path, family="mlp")
