"""Auto Tiny Classifiers — the paper's core contribution in JAX.

Public surface:
  * CircuitSpec / Genome            — genome.py
  * EncodingConfig / fit_encoder    — encoding.py
  * EvolveConfig / evolve           — evolve.py
  * AutoTinyClassifier              — api.py (sklearn-style end-to-end flow)
"""
from repro.core.genome import CircuitSpec, Genome, init_genome  # noqa: F401
from repro.core.encoding import (  # noqa: F401
    EncodingConfig,
    PackedDataset,
    fit_encoder,
    encode,
    pack_dataset,
    split_masks,
)
from repro.core.evolve import EvolveConfig, EvolveState, evolve, evolve_packed  # noqa: F401
