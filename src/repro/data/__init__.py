from repro.data.tabular import (  # noqa: F401
    DATASETS,
    TabularDataset,
    kfold,
    load_dataset,
    train_test_split,
)
