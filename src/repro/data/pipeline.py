"""Deterministic, resumable token data pipeline.

`TokenStream` is *stateless-indexed*: batch(step) is a pure function of
(seed, step, shard), so a restarted job replays exactly the batches it would
have seen — checkpoint/restart is bitwise reproducible (tested), and elastic
restarts just change the shard grid.  A background prefetch thread hides
host-side batch synthesis (stands in for the storage reader of a real
deployment).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenStream:
    def __init__(
        self,
        vocab: int,
        batch: int,
        seq_len: int,
        seed: int = 0,
        shard_index: int = 0,
        shard_count: int = 1,
        structured: bool = True,
    ):
        assert batch % shard_count == 0
        self.vocab = vocab
        self.batch = batch
        self.local_batch = batch // shard_count
        self.seq_len = seq_len
        self.seed = seed
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.structured = structured

    def _bigram_table(self) -> np.ndarray:
        """Fixed (per-seed) next-token map — the learnable structure."""
        return np.random.RandomState(self.seed).permutation(self.vocab)

    def batch_at(self, step: int) -> dict:
        """Pure function of step → {"tokens", "labels"} (local shard)."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) * 97 + self.shard_index
        )
        b, s, v = self.local_batch, self.seq_len, self.vocab
        if self.structured:
            # 80 % of transitions follow a fixed bigram map t→table[t];
            # a small model learns it within tens of steps (tested), and the
            # mapping is stable across steps/shards → resumable + learnable.
            table = self._bigram_table()
            seq = np.empty((b, s + 1), dtype=np.int64)
            seq[:, 0] = rng.randint(0, v, b)
            follow = rng.rand(b, s) < 0.8
            noise = rng.randint(0, v, (b, s))
            for t in range(s):
                seq[:, t + 1] = np.where(
                    follow[:, t], table[seq[:, t]], noise[:, t]
                )
            tokens = seq[:, :-1]
            labels = seq[:, 1:]
        else:
            tokens = rng.randint(0, v, (b, s))
            labels = rng.randint(0, v, (b, s))
        return {
            "tokens": tokens.astype(np.int32),
            "labels": labels.astype(np.int32),
        }

    def prefetching(self, start_step: int, depth: int = 2):
        """Generator with a background prefetch thread."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch_at(step)))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass
