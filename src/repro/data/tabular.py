"""Tabular dataset substrate (paper Table 1).

The container is offline, so the 33 OpenML/UCI/Kaggle datasets are
represented by deterministic synthetic generators *matched to Table 1*
(rows, features, classes, and a per-dataset difficulty drawn from the
dataset-name hash).  Targets are generated from random decision-tree rules
over a subset of informative features plus label noise — the regime where
tree-based models beat DNNs (Grinsztajn et al., quoted in the paper §1):
irregular target patterns, uninformative features, non rotationally-
invariant data.

`iris` is generated from the published per-class Gaussian statistics of the
real UCI iris data (means/stds per feature per species) — documented
deviation, see DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class TabularDataset:
    name: str
    x: np.ndarray         # float32[R, F]
    y: np.ndarray         # int64[R] in [0, n_classes)
    n_classes: int

    @property
    def n_rows(self) -> int:
        return self.x.shape[0]

    @property
    def n_features(self) -> int:
        return self.x.shape[1]


# name: (classes, rows, features, in_autogluon_paper)  — paper Table 1.
DATASETS: dict[str, tuple[int, int, int, bool]] = {
    "vehicle": (2, 846, 22, True),
    "cars": (3, 406, 8, True),
    "user-model-data": (4, 403, 5, False),
    "kc1": (2, 145, 95, True),
    "phoneme": (2, 5404, 6, True),
    "skin-seg": (2, 245057, 4, False),
    "ecoli-data": (4, 336, 8, False),
    "iris": (3, 150, 7, False),
    "blood": (2, 748, 4, True),
    "higgs": (2, 98050, 29, True),
    "wifi-localization": (4, 2000, 7, False),
    "nomao": (2, 34465, 119, True),
    "olinda-outlier": (4, 75, 3, False),
    "australian": (2, 690, 15, True),
    "segment": (2, 2310, 20, True),
    "led": (10, 500, 7, False),
    "numerai": (2, 96320, 22, True),
    "miniboone": (2, 130064, 51, True),
    "wall-robot": (4, 5456, 3, False),
    "jasmine": (2, 2984, 145, True),
    "yeast": (10, 1484, 8, False),
    "christine": (2, 5418, 1637, True),
    "sylvine": (2, 5124, 21, True),
    "seismic-bumps": (3, 210, 8, False),
    "ccfraud": (2, 284807, 31, False),
    "clickpred": (2, 1496391, 10, False),
    "vowel": (2, 528, 21, False),
    "nursery": (5, 12958, 9, False),
    "spectf-data": (2, 267, 45, False),
    "teaching-assist": (3, 151, 7, False),
    "wisconsin": (2, 194, 33, False),
    "sonar": (2, 208, 61, False),
    "ionosphere": (2, 351, 35, False),
}

# Published UCI iris per-class feature means / stds (sepal-l, sepal-w,
# petal-l, petal-w); 3 extra synthetic features pad to Table 1's 7.
_IRIS_STATS = {
    0: ([5.006, 3.428, 1.462, 0.246], [0.352, 0.379, 0.174, 0.105]),
    1: ([5.936, 2.770, 4.260, 1.326], [0.516, 0.314, 0.470, 0.198]),
    2: ([6.588, 2.974, 5.552, 2.026], [0.636, 0.322, 0.552, 0.275]),
}


def _name_seed(name: str) -> int:
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")


def _tree_rule_labels(
    rng: np.random.RandomState, x: np.ndarray, n_classes: int, depth: int
) -> np.ndarray:
    """Label rows by a random axis-aligned decision tree over x."""
    r = x.shape[0]
    y = np.zeros(r, dtype=np.int64)
    idx_stack = [(np.arange(r), 0)]
    leaf_class = 0
    while idx_stack:
        idx, d = idx_stack.pop()
        if d == depth or len(idx) == 0:
            if len(idx):
                y[idx] = leaf_class % n_classes
                leaf_class += 1
            continue
        f = rng.randint(x.shape[1])
        vals = x[idx, f]
        thr = np.quantile(vals, rng.uniform(0.25, 0.75)) if len(idx) > 4 else 0.0
        left = idx[vals <= thr]
        right = idx[vals > thr]
        idx_stack.append((left, d + 1))
        idx_stack.append((right, d + 1))
    return y


def _synth(name: str, n_classes: int, rows: int, feats: int) -> TabularDataset:
    seed = _name_seed(name)
    rng = np.random.RandomState(seed)
    # difficulty knobs drawn from the name hash
    noise = 0.03 + (seed % 97) / 97 * 0.22          # label noise 3–25 %
    frac_informative = 0.4 + (seed % 53) / 53 * 0.5  # 40–90 % informative
    n_inf = max(2, int(feats * frac_informative)) if feats > 2 else feats
    depth = int(np.clip(2 + (seed % 5), 2, 6))

    x = rng.randn(rows, feats).astype(np.float32)
    # heterogeneous columns: make ~1/3 categorical-ish (few distinct values)
    n_cat = feats // 3
    for j in range(n_cat):
        k = 2 + (seed + j) % 6
        x[:, j] = np.floor(
            (x[:, j] - x[:, j].min()) / (np.ptp(x[:, j]) + 1e-6) * k
        )
    y = _tree_rule_labels(rng, x[:, :n_inf], n_classes, depth)
    flip = rng.rand(rows) < noise
    y[flip] = rng.randint(0, n_classes, flip.sum())
    return TabularDataset(name=name, x=x, y=y, n_classes=n_classes)


def _iris() -> TabularDataset:
    rng = np.random.RandomState(_name_seed("iris"))
    xs, ys = [], []
    for c, (mu, sd) in _IRIS_STATS.items():
        n = 50
        base = rng.randn(n, 4) * np.asarray(sd) + np.asarray(mu)
        extra = rng.randn(n, 3) * 0.5  # uninformative padding features
        xs.append(np.concatenate([base, extra], axis=1))
        ys.append(np.full(n, c, dtype=np.int64))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys)
    perm = rng.permutation(len(y))
    return TabularDataset("iris", x[perm], y[perm], 3)


def load_dataset(name: str, max_rows: int | None = None) -> TabularDataset:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(DATASETS)}")
    c, r, f, _ = DATASETS[name]
    ds = _iris() if name == "iris" else _synth(name, c, r, f)
    if max_rows is not None and ds.n_rows > max_rows:
        rng = np.random.RandomState(0)
        idx = rng.choice(ds.n_rows, max_rows, replace=False)
        ds = TabularDataset(ds.name, ds.x[idx], ds.y[idx], ds.n_classes)
    return ds


def train_test_split(
    ds: TabularDataset, test_fraction: float = 0.2, seed: int = 0
) -> tuple[TabularDataset, TabularDataset]:
    """Paper §5: 80 % train / 20 % test."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(ds.n_rows)
    n_test = int(round(ds.n_rows * test_fraction))
    te, tr = perm[:n_test], perm[n_test:]
    mk = lambda i: TabularDataset(ds.name, ds.x[i], ds.y[i], ds.n_classes)
    return mk(tr), mk(te)


def kfold(ds: TabularDataset, k: int = 10, seed: int = 0):
    """Yield (train, test) folds — the paper's Fig. 10 robustness study."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(ds.n_rows)
    folds = np.array_split(perm, k)
    mk = lambda i: TabularDataset(ds.name, ds.x[i], ds.y[i], ds.n_classes)
    for f in range(k):
        te = folds[f]
        tr = np.concatenate([folds[j] for j in range(k) if j != f])
        yield mk(tr), mk(te)
