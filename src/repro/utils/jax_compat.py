"""Portability shims for jax APIs that moved between 0.4.x and 0.7.x.

The repo targets current jax idioms (`jax.shard_map` with ``check_vma``,
`jax.make_mesh` with ``axis_types``); this module lets the same call sites
run on the 0.4.x line too, where shard_map still lives under
`jax.experimental` (with the ``check_rep`` spelling) and `make_mesh` has no
``axis_types`` parameter.  Import from here instead of calling the moved
APIs directly.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def make_mesh(axis_shapes, axis_names) -> "jax.sharding.Mesh":
    """`jax.make_mesh` with explicit-Auto axis types where supported."""
    if _AxisType is not None:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(_AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map`, falling back to the experimental spelling.

    ``check_vma`` maps onto the old ``check_rep`` flag — both toggle the
    per-axis replication/varying-mesh-axes check.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
