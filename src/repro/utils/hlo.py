"""Parse compiled (post-SPMD) HLO text for collective traffic.

`compiled.cost_analysis()` does not attribute collective bytes, so the
roofline's collective term comes from summing the result-buffer sizes of
every collective op in the optimized HLO, weighted by the op's wire-traffic
factor for ring algorithms:

    all-reduce          2·size·(n-1)/n  ≈ 2×   (reduce-scatter + all-gather)
    all-gather          1·size·(n-1)/n  ≈ 1×   (result = gathered buffer)
    reduce-scatter      1·input ≈ result·n ... counted via operand
    all-to-all          1×
    collective-permute  1×

We report both the raw per-op byte totals and the weighted sum; the
approximation (ring algorithms, (n-1)/n → 1) is recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  %all-gather.3 = bf16[2,1024,512]{2,1,0} all-gather(...)
#       ROOT %tuple ... all-reduce-start(
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _size_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """→ {op: {"count", "bytes"}, "weighted_bytes": float}."""
    per_op: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        # async pairs (-start/-done) appear twice; count the start only
        span = m.group(0)
        if "-done(" in span:
            continue
        sz = _size_bytes(dtype, dims)
        per_op[op]["count"] += 1
        per_op[op]["bytes"] += sz
    weighted = sum(
        _WEIGHT[op] * st["bytes"] for op, st in per_op.items()
    )
    out = {op: dict(st) for op, st in per_op.items()}
    out["weighted_bytes"] = float(weighted)
    _ = seen_done
    return out


def count_op(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"\b{re.escape(opcode)}\(", hlo_text))
