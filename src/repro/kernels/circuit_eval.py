"""Pallas TPU kernel: bit-packed sea-of-gates circuit evaluation.

This is the compute hot-spot of Auto Tiny Classifiers: every generation
evaluates λ candidate circuits over the full training+validation set
(population × rows × gates boolean ops).  TPU-native design (DESIGN.md §3):

  * dataset rows are bit-packed 32/uint32 word; the word axis is the *lane*
    axis (VPU-friendly, 128-word tiles) — one ALU op evaluates 32 rows;
  * the genome (opcodes / edge list / output taps) drives control flow and
    VMEM addressing, so it rides in SMEM via scalar prefetch;
  * each grid cell materialises the (I+n)-signal node-value table for its
    word block in a VMEM scratch buffer and walks the gates sequentially
    (the circuit is a DAG in topological index order — node i only reads
    signals < I+i, so a single forward sweep suffices);
  * grid = (population, word-blocks): embarrassingly parallel, no reductions.

VMEM footprint per cell: (I + n + O) × block_words × 4 B (+ the x block).
For the paper's regime (I ≲ 6.5k bits, n = 300) a 512-word block is ≤ ~14 MB
worst-case and ~0.8 MB for typical datasets; `ops.py` shrinks the block when
the table would overflow VMEM.

Validated in interpret mode against `ref.py` (tests/test_kernels.py sweeps
shapes, function sets and dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import gates

LANE = 128  # TPU lane count; word blocks are multiples of this


def _gate_select(op, a, b):
    """Opcode-indexed gate on uint32 words (VPU select chain)."""
    r = jnp.where(op == gates.AND, a & b, jnp.uint32(0))
    r = jnp.where(op == gates.OR, a | b, r)
    r = jnp.where(op == gates.NAND, ~(a & b), r)
    r = jnp.where(op == gates.NOR, ~(a | b), r)
    r = jnp.where(op == gates.XOR, a ^ b, r)
    r = jnp.where(op == gates.XNOR, ~(a ^ b), r)
    r = jnp.where(op == gates.NOT_A, ~a, r)
    r = jnp.where(op == gates.BUF_A, a, r)
    return r


def _kernel(
    # scalar-prefetch (SMEM):
    opcodes_ref,   # i32[P, n]
    edge_src_ref,  # i32[P, n, 2]
    out_src_ref,   # i32[P, O]
    # VMEM blocks:
    x_ref,         # u32[I, BW]
    o_ref,         # u32[1, O, BW]
    # scratch:
    vals_ref,      # u32[I+n, BW]
):
    p = pl.program_id(0)
    n_in = x_ref.shape[0]
    n_nodes = opcodes_ref.shape[1]
    n_out = out_src_ref.shape[1]

    # Seed the node-value table with the input bits.
    vals_ref[:n_in, :] = x_ref[...]

    def body(i, _):
        a_idx = edge_src_ref[p, i, 0]
        b_idx = edge_src_ref[p, i, 1]
        op = opcodes_ref[p, i]
        a = vals_ref[a_idx, :]
        b = vals_ref[b_idx, :]
        vals_ref[n_in + i, :] = _gate_select(op, a, b)
        return 0

    jax.lax.fori_loop(0, n_nodes, body, 0)

    for j in range(n_out):  # O is small and static — unrolled taps
        o_ref[0, j, :] = vals_ref[out_src_ref[p, j], :]


def _spans_kernel(
    # scalar-prefetch (SMEM):
    opcodes_ref,   # i32[P, n]
    edge_src_ref,  # i32[P, n, 2]
    out_src_ref,   # i32[P, O]
    block_off_ref,  # i32[P]  word-block offset of circuit p's span
    in_width_ref,   # i32[P]  live input rows of circuit p (rest masked to 0)
    # VMEM blocks:
    x_ref,         # u32[I_max, BW]  (block taken at block_off[p] + wb)
    o_ref,         # u32[1, O, BW]
    # scratch:
    vals_ref,      # u32[I_max+n, BW]
):
    """Span variant of `_kernel` for multi-tenant serving.

    Each circuit p owns a contiguous run of word blocks (its tenant's
    micro-batch) starting at ``block_off[p]`` — the x BlockSpec index_map
    reads the prefetched offsets, so one launch walks P disjoint spans
    instead of P × W full sweeps.  Input rows at or above ``in_width[p]``
    are zero-masked when seeding the node-value table: a tenant narrower
    than I_max can never observe another tenant's bits, even through a
    corrupted genome whose edges index past its own inputs.
    """
    p = pl.program_id(0)
    n_in = x_ref.shape[0]
    n_nodes = opcodes_ref.shape[1]
    n_out = out_src_ref.shape[1]

    row = jax.lax.broadcasted_iota(jnp.int32, x_ref.shape, 0)
    vals_ref[:n_in, :] = jnp.where(
        row < in_width_ref[p], x_ref[...], jnp.uint32(0)
    )

    def body(i, _):
        a_idx = edge_src_ref[p, i, 0]
        b_idx = edge_src_ref[p, i, 1]
        op = opcodes_ref[p, i]
        a = vals_ref[a_idx, :]
        b = vals_ref[b_idx, :]
        vals_ref[n_in + i, :] = _gate_select(op, a, b)
        return 0

    jax.lax.fori_loop(0, n_nodes, body, 0)

    for j in range(n_out):
        o_ref[0, j, :] = vals_ref[out_src_ref[p, j], :]


@functools.partial(
    jax.jit, static_argnames=("span_words", "block_words", "interpret")
)
def eval_population_spans_kernel(
    opcodes: jax.Array,    # i32[P, n]
    edge_src: jax.Array,   # i32[P, n, 2]
    out_src: jax.Array,    # i32[P, O]
    x_words: jax.Array,    # u32[I_max, W_total]
    word_off: jax.Array,   # i32[P]  word offset of circuit p's span
    in_width: jax.Array,   # i32[P]  live input rows per circuit
    *,
    span_words: int,       # words each circuit evaluates (multiple of block)
    block_words: int = 512,
    interpret: bool = False,
) -> jax.Array:            # u32[P, O, span_words]
    pop, n = opcodes.shape
    n_in, w = x_words.shape
    n_out = out_src.shape[1]
    assert span_words % block_words == 0, (span_words, block_words)
    assert w % block_words == 0, (w, block_words)
    grid = (pop, span_words // block_words)
    block_off = word_off.astype(jnp.int32) // block_words

    return pl.pallas_call(
        _spans_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (n_in, block_words),
                    lambda p, wb, opc, es, osrc, boff, iw: (0, boff[p] + wb),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, n_out, block_words), lambda p, wb, *_: (p, 0, wb)
            ),
            scratch_shapes=[pltpu.VMEM((n_in + n, block_words), jnp.uint32)],
        ),
        out_shape=jax.ShapeDtypeStruct((pop, n_out, span_words), jnp.uint32),
        interpret=interpret,
    )(opcodes, edge_src, out_src, block_off, in_width.astype(jnp.int32),
      x_words)


@functools.partial(
    jax.jit, static_argnames=("block_words", "interpret")
)
def eval_population_kernel(
    opcodes: jax.Array,   # i32[P, n]
    edge_src: jax.Array,  # i32[P, n, 2]
    out_src: jax.Array,   # i32[P, O]
    x_words: jax.Array,   # u32[I, W]  (W must be a multiple of block_words)
    *,
    block_words: int = 512,
    interpret: bool = False,
) -> jax.Array:           # u32[P, O, W]
    pop, n = opcodes.shape
    n_in, w = x_words.shape
    n_out = out_src.shape[1]
    assert w % block_words == 0, (w, block_words)
    grid = (pop, w // block_words)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((n_in, block_words), lambda p, wb, *_: (0, wb)),
            ],
            out_specs=pl.BlockSpec(
                (1, n_out, block_words), lambda p, wb, *_: (p, 0, wb)
            ),
            scratch_shapes=[pltpu.VMEM((n_in + n, block_words), jnp.uint32)],
        ),
        out_shape=jax.ShapeDtypeStruct((pop, n_out, w), jnp.uint32),
        interpret=interpret,
    )(opcodes, edge_src, out_src, x_words)
