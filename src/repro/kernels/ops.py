"""Backend-dispatching wrappers around circuit evaluation (legacy surface).

Evaluation strategy lives in the `repro.runtime` backend registry —
``"ref"`` (pure-jnp oracle), ``"pallas"`` (TPU kernel, interpret on CPU),
``"pallas-gpu"`` (reserved).  New code should resolve a backend once at
its API boundary (`repro.runtime.resolve_backend`) and call its methods;
these wrappers remain as the module-level convenience surface.

The one-release ``use_kernel=``/``interpret=`` deprecation shim promised
in the backend-registry redesign has been **removed**: passing either is
now a `TypeError`.  Migrate to ``backend="ref" | "pallas"`` or an
`EvalBackend` instance (``PallasBackend(interpret=...)`` forces a mode).
"""
from __future__ import annotations

import jax

from repro import runtime


def eval_population(
    opcodes: jax.Array,   # i32[P, n]
    edge_src: jax.Array,  # i32[P, n, 2]
    out_src: jax.Array,   # i32[P, O]
    x_words: jax.Array,   # u32[I, W]
    *,
    backend: "str | runtime.EvalBackend" = "ref",
) -> jax.Array:           # u32[P, O, W]
    """Evaluate a population of circuits on a shared packed dataset."""
    be = runtime.resolve_backend(backend)
    return be.eval_population(opcodes, edge_src, out_src, x_words)


def eval_population_spans(
    opcodes: jax.Array,    # i32[P, n]
    edge_src: jax.Array,   # i32[P, n, 2]
    out_src: jax.Array,    # i32[P, O]
    x_words: jax.Array,    # u32[I_max, W_total] fused multi-tenant buffer
    word_off: jax.Array,   # i32[P] word offset of circuit p's span
    in_width: jax.Array,   # i32[P] live input rows of circuit p
    *,
    span_words: int,
    backend: "str | runtime.EvalBackend" = "ref",
) -> jax.Array:            # u32[P, O, span_words]
    """Multi-tenant population eval: circuit p reads only its own span of
    ``span_words`` words, with per-circuit input-width masking.

    This is the serving hot path (`repro.serve.circuits`): all tenants'
    micro-batches are packed side by side on the word axis and one launch
    evaluates every tenant on its own rows — P spans instead of a P × W_total
    full sweep.  ``word_off`` entries must be multiples of ``span_words``
    (the serving engine lays spans out back to back); the kernel path
    rejects misaligned concrete offsets rather than truncating them.
    """
    be = runtime.resolve_backend(backend)
    return be.eval_population_spans(
        opcodes, edge_src, out_src, x_words, word_off, in_width,
        span_words=span_words,
    )


def eval_circuit(
    opcodes,
    edge_src,
    out_src,
    x_words,
    *,
    backend: "str | runtime.EvalBackend" = "ref",
) -> jax.Array:
    """Single-circuit convenience wrapper → u32[O, W]."""
    be = runtime.resolve_backend(backend)
    return be.eval_circuit(opcodes, edge_src, out_src, x_words)
