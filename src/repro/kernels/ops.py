"""Jit'd public wrappers around the circuit-evaluation kernel.

Dispatches between the Pallas TPU kernel (`circuit_eval.py`) and the pure-jnp
oracle (`ref.py`).  On CPU (this container) the kernel runs in interpret mode;
on TPU it compiles natively.  The wrapper pads the word axis to the kernel's
lane-aligned block size and picks a block that keeps the VMEM node-value
table within budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import circuit_eval, ref

VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # leave headroom out of ~16 MB/core


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_block_words(n_signals: int, w: int, lane: int = circuit_eval.LANE) -> int:
    """Largest lane-multiple block whose (I+n)-row uint32 table fits VMEM."""
    max_words = max(VMEM_BUDGET_BYTES // (4 * max(n_signals, 1)), lane)
    block = (max_words // lane) * lane
    block = min(block, 4 * lane)  # cap: 512 words = 16k rows per cell
    # no point exceeding the (padded) word count itself
    w_padded = ((w + lane - 1) // lane) * lane
    return min(block, w_padded)


def eval_population(
    opcodes: jax.Array,   # i32[P, n]
    edge_src: jax.Array,  # i32[P, n, 2]
    out_src: jax.Array,   # i32[P, O]
    x_words: jax.Array,   # u32[I, W]
    *,
    use_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:           # u32[P, O, W]
    """Evaluate a population of circuits on a shared packed dataset."""
    if not use_kernel:
        return ref.eval_population_packed(opcodes, edge_src, out_src, x_words)

    n_in, w = x_words.shape
    n = opcodes.shape[1]
    block = pick_block_words(n_in + n, w)
    w_pad = ((w + block - 1) // block) * block
    if w_pad != w:
        x_words = jnp.pad(x_words, ((0, 0), (0, w_pad - w)))
    out = circuit_eval.eval_population_kernel(
        opcodes.astype(jnp.int32),
        edge_src.astype(jnp.int32),
        out_src.astype(jnp.int32),
        x_words.astype(jnp.uint32),
        block_words=block,
        interpret=(not _on_tpu()) if interpret is None else interpret,
    )
    return out[..., :w]


def eval_circuit(
    opcodes, edge_src, out_src, x_words, *, use_kernel: bool = False, interpret=None
) -> jax.Array:
    """Single-circuit convenience wrapper → u32[O, W]."""
    out = eval_population(
        opcodes[None], edge_src[None], out_src[None], x_words,
        use_kernel=use_kernel, interpret=interpret,
    )
    return out[0]
