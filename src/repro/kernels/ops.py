"""Jit'd public wrappers around the circuit-evaluation kernel.

Dispatches between the Pallas TPU kernel (`circuit_eval.py`) and the pure-jnp
oracle (`ref.py`).  On CPU (this container) the kernel runs in interpret mode;
on TPU it compiles natively.  The wrapper pads the word axis to the kernel's
lane-aligned block size and picks a block that keeps the VMEM node-value
table within budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import circuit_eval, ref

VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # leave headroom out of ~16 MB/core


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_block_words(n_signals: int, w: int, lane: int = circuit_eval.LANE) -> int:
    """Largest lane-multiple block whose (I+n)-row uint32 table fits VMEM."""
    max_words = max(VMEM_BUDGET_BYTES // (4 * max(n_signals, 1)), lane)
    block = (max_words // lane) * lane
    block = min(block, 4 * lane)  # cap: 512 words = 16k rows per cell
    # no point exceeding the (padded) word count itself
    w_padded = ((w + lane - 1) // lane) * lane
    return min(block, w_padded)


def eval_population(
    opcodes: jax.Array,   # i32[P, n]
    edge_src: jax.Array,  # i32[P, n, 2]
    out_src: jax.Array,   # i32[P, O]
    x_words: jax.Array,   # u32[I, W]
    *,
    use_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:           # u32[P, O, W]
    """Evaluate a population of circuits on a shared packed dataset."""
    if not use_kernel:
        return ref.eval_population_packed(opcodes, edge_src, out_src, x_words)

    n_in, w = x_words.shape
    n = opcodes.shape[1]
    block = pick_block_words(n_in + n, w)
    w_pad = ((w + block - 1) // block) * block
    if w_pad != w:
        x_words = jnp.pad(x_words, ((0, 0), (0, w_pad - w)))
    out = circuit_eval.eval_population_kernel(
        opcodes.astype(jnp.int32),
        edge_src.astype(jnp.int32),
        out_src.astype(jnp.int32),
        x_words.astype(jnp.uint32),
        block_words=block,
        interpret=(not _on_tpu()) if interpret is None else interpret,
    )
    return out[..., :w]


@functools.partial(jax.jit, static_argnames=("span_words",))
def _spans_ref(opcodes, edge_src, out_src, x_words, word_off, in_width,
               span_words):
    return ref.eval_population_spans_packed(
        opcodes, edge_src, out_src, x_words, word_off, in_width,
        span_words=span_words,
    )


def eval_population_spans(
    opcodes: jax.Array,    # i32[P, n]
    edge_src: jax.Array,   # i32[P, n, 2]
    out_src: jax.Array,    # i32[P, O]
    x_words: jax.Array,    # u32[I_max, W_total] fused multi-tenant buffer
    word_off: jax.Array,   # i32[P] word offset of circuit p's span
    in_width: jax.Array,   # i32[P] live input rows of circuit p
    *,
    span_words: int,
    use_kernel: bool = False,
    interpret: bool | None = None,
) -> jax.Array:            # u32[P, O, span_words]
    """Multi-tenant population eval: circuit p reads only its own span of
    ``span_words`` words, with per-circuit input-width masking.

    This is the serving hot path (`repro.serve.circuits`): all tenants'
    micro-batches are packed side by side on the word axis and one launch
    evaluates every tenant on its own rows — P spans instead of a P × W_total
    full sweep.  ``word_off`` entries must be multiples of ``span_words``
    (the serving engine lays spans out back to back); the kernel path
    rejects misaligned concrete offsets rather than truncating them.
    """
    if not use_kernel:
        return _spans_ref(
            opcodes, edge_src, out_src, x_words,
            word_off.astype(jnp.int32), in_width.astype(jnp.int32),
            span_words,
        )

    n_in, w = x_words.shape
    n = opcodes.shape[1]
    block = pick_block_words(n_in + n, span_words)
    if span_words % block or w % block:
        block = span_words  # fall back to one block per span
    # block | span_words holds here, so offsets that honour the documented
    # multiple-of-span contract are block-aligned; the kernel's integer
    # division would silently evaluate the wrong span otherwise.
    if not isinstance(word_off, jax.core.Tracer):
        off = np.asarray(word_off)
        if off.size and (off % block).any():
            raise ValueError(
                f"word_off entries must be multiples of span_words"
                f"={span_words} (kernel block {block}); got {off.tolist()}"
            )
    return circuit_eval.eval_population_spans_kernel(
        opcodes.astype(jnp.int32),
        edge_src.astype(jnp.int32),
        out_src.astype(jnp.int32),
        x_words.astype(jnp.uint32),
        word_off.astype(jnp.int32),
        in_width.astype(jnp.int32),
        span_words=span_words,
        block_words=block,
        interpret=(not _on_tpu()) if interpret is None else interpret,
    )


def eval_circuit(
    opcodes, edge_src, out_src, x_words, *, use_kernel: bool = False, interpret=None
) -> jax.Array:
    """Single-circuit convenience wrapper → u32[O, W]."""
    out = eval_population(
        opcodes[None], edge_src[None], out_src[None], x_words,
        use_kernel=use_kernel, interpret=interpret,
    )
    return out[0]
