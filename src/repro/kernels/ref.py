"""Pure-jnp oracle for bit-packed circuit evaluation.

This is the reference implementation the Pallas kernel
(`repro.kernels.circuit_eval`) is validated against (assert_allclose in
tests/test_kernels.py over shape/dtype sweeps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import gates


def eval_circuit_packed(
    opcodes: jax.Array,   # int32[n]    raw gate opcodes
    edge_src: jax.Array,  # int32[n,2]  operand ids, < I+i for node i
    out_src: jax.Array,   # int32[O]    output taps, < I+n
    x_words: jax.Array,   # uint32[I,W] packed input bits
) -> jax.Array:           # uint32[O,W] packed output bits
    """Evaluate one circuit on all packed rows."""
    n = opcodes.shape[0]
    i_in, w = x_words.shape
    vals = jnp.concatenate(
        [x_words.astype(jnp.uint32), jnp.zeros((n, w), jnp.uint32)], axis=0
    )

    def body(i, vals):
        a = vals[edge_src[i, 0]]
        b = vals[edge_src[i, 1]]
        r = gates.apply_gates_packed(opcodes[i], a, b)
        return jax.lax.dynamic_update_slice(vals, r[None], (i_in + i, 0))

    vals = jax.lax.fori_loop(0, n, body, vals)
    return vals[out_src]


def eval_population_packed(opcodes, edge_src, out_src, x_words):
    """vmap over a leading population axis on the genome arrays; the packed
    dataset is shared."""
    return jax.vmap(eval_circuit_packed, in_axes=(0, 0, 0, None))(
        opcodes, edge_src, out_src, x_words
    )


def eval_circuit_span(
    opcodes, edge_src, out_src, x_words, word_off, in_width, *, span_words: int
):
    """Evaluate one circuit on the ``span_words`` words starting at
    ``word_off``, with input rows >= ``in_width`` masked to zero (the
    multi-tenant isolation contract of the spans kernel)."""
    n_in = x_words.shape[0]
    x = jax.lax.dynamic_slice(
        x_words,
        (jnp.zeros((), jnp.int32), word_off.astype(jnp.int32)),
        (n_in, span_words),
    )
    row = jnp.arange(n_in, dtype=jnp.int32)[:, None]
    x = jnp.where(row < in_width, x, jnp.uint32(0))
    return eval_circuit_packed(opcodes, edge_src, out_src, x)


def eval_population_spans_packed(
    opcodes, edge_src, out_src, x_words, word_off, in_width, *, span_words: int
):
    """Per-circuit word spans: circuit p reads words
    [word_off[p], word_off[p] + span_words) of the shared buffer.  Oracle for
    `circuit_eval.eval_population_spans_kernel` → uint32[P, O, span_words]."""
    f = functools.partial(eval_circuit_span, span_words=span_words)
    return jax.vmap(f, in_axes=(0, 0, 0, None, 0, 0))(
        opcodes, edge_src, out_src, x_words, word_off, in_width
    )


def eval_circuit_rows(opcodes, edge_src, out_src, x_bits):
    """Unpacked row-wise reference (uint8[R, I] → uint8[R, O]).

    Slow O(R·n) path used only by tests to validate the packed layout itself.
    """
    n = opcodes.shape[0]
    r, i_in = x_bits.shape
    vals = jnp.concatenate(
        [x_bits.astype(jnp.uint32).T, jnp.zeros((n, r), jnp.uint32)], axis=0
    )

    def body(i, vals):
        a = vals[edge_src[i, 0]]
        b = vals[edge_src[i, 1]]
        out = gates.apply_gates_packed(opcodes[i], a, b) & jnp.uint32(1)
        return jax.lax.dynamic_update_slice(vals, out[None], (i_in + i, 0))

    vals = jax.lax.fori_loop(0, n, body, vals)
    return vals[out_src].T.astype(jnp.uint8)
